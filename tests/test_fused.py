"""Fused threshold->pack epilogue + megakernel + autotuner contracts.

The fully-binary hot path (ISSUE 2): with pack_out=True the kernels
emit uint32 sign words straight from the GEMM epilogue, so the
inter-layer activation never exists in HBM as int32.  These tests pin
(1) bit-exactness of the fused path vs the xla oracle over odd K/N,
(2) the VMEM-residency property itself (no int32 [M, N] intermediate
in the fused jaxpr), (3) the megakernel vs the chained / dense-sign
oracles, (4) the folded-BN -> per-channel-threshold rewrite, (5) the
clamp-to-divisor block logic and its ValueErrors, and (6) the tuning
table."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or self-skip shim

from repro.analysis.jaxpr_audit import eqn_shapes
from repro.core.bnn_layers import (bnn_dense_serve_folded,
                                   bnn_mlp_serve_folded,
                                   fold_to_channel_thresholds,
                                   quantize_for_serving)
from repro.kernels import ref
from repro.kernels.autotune import (BlockConfig, autotune, best_blocks,
                                    get_table)
from repro.kernels.fused_mlp import fused_binary_mlp
from repro.kernels.ops import binarize_pack, binary_binary_dense, \
    binary_dense
from repro.kernels.packed import PackedArray, pack_words
from repro.kernels.popcount_gemm import popcount_gemm
from repro.kernels.xnor_gemm import xnor_gemm


def _pm1(rng, *shape):
    return rng.choice([-1.0, 1.0], size=shape).astype(np.float32)


# ------------------------------------------------------------------ #
# fused epilogue: cross-backend bit-exactness                          #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("m,k,n", [(37, 50, 20), (5, 97, 33), (64, 128, 96),
                                   (3, 33, 65)])
@pytest.mark.parametrize("thr", ["scalar", "vector"])
def test_pack_out_bit_exact_odd_shapes(m, k, n, thr):
    """pallas-interpret fused pack_out vs the xla oracle: identical
    uint32 words (incl. zeroed pad bits) on deliberately odd K/N."""
    rng = np.random.default_rng(m * 31 + k * 7 + n)
    xs, ws = _pm1(rng, m, k), _pm1(rng, n, k)
    xp = PackedArray.pack(jnp.asarray(xs))
    wp = PackedArray.pack(jnp.asarray(ws))
    t = 2 if thr == "scalar" else jnp.asarray(
        rng.integers(-5, 5, size=n).astype(np.int32))
    y_i = binary_binary_dense(xp, wp, threshold=t, pack_out=True,
                              backend="interpret")
    y_x = binary_binary_dense(xp, wp, threshold=t, pack_out=True,
                              backend="xla")
    assert isinstance(y_i, PackedArray) and isinstance(y_x, PackedArray)
    assert y_i.length == y_x.length == n
    assert y_i.words.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(y_i.words),
                                  np.asarray(y_x.words))
    # and both equal the dense sign oracle
    tnp = 2 if thr == "scalar" else np.asarray(t)
    dec = np.where(xs @ ws.T >= tnp, 1.0, -1.0)
    want = pack_words(jnp.asarray(dec), axis=-1)
    np.testing.assert_array_equal(np.asarray(y_i.words), np.asarray(want))


@given(st.integers(1, 80), st.integers(1, 100), st.integers(1, 70),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_pack_out_matches_oracle(m, k, n, seed):
    """Property: for ANY shape (odd K/N included) the fused epilogue's
    words match the oracle's pack of the thresholded dense dot."""
    rng = np.random.default_rng(seed)
    xs, ws = _pm1(rng, m, k), _pm1(rng, n, k)
    xp = PackedArray.pack(jnp.asarray(xs))
    wp = PackedArray.pack(jnp.asarray(ws))
    y = binary_binary_dense(xp, wp, threshold=0, pack_out=True,
                            backend="interpret")
    dec = np.where(xs @ ws.T >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(
        np.asarray(y.words),
        np.asarray(pack_words(jnp.asarray(dec), axis=-1)))


def test_binary_dense_pack_out():
    """The float->binary boundary layer: xnor_gemm's fused epilogue."""
    rng = np.random.default_rng(11)
    m, k, n = 37, 96, 40
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = _pm1(rng, k, n)
    wp = PackedArray.pack(jnp.asarray(w), axis=0)
    alpha = jnp.asarray(rng.uniform(0.5, 2.0, size=n).astype(np.float32))
    p_i = binary_dense(x, wp, alpha, threshold=0.0, pack_out=True,
                       backend="interpret")
    p_x = binary_dense(x, wp, alpha, threshold=0.0, pack_out=True,
                       backend="xla")
    assert p_i.length == p_x.length == n
    np.testing.assert_array_equal(np.asarray(p_i.words),
                                  np.asarray(p_x.words))


def test_unfused_and_fused_agree():
    """pack_out=True must equal the two-step threshold-then-
    binarize_pack chain bit for bit (the path it replaces)."""
    rng = np.random.default_rng(5)
    m, k, n = 40, 70, 50
    xs, ws = _pm1(rng, m, k), _pm1(rng, n, k)
    xp = PackedArray.pack(jnp.asarray(xs))
    wp = PackedArray.pack(jnp.asarray(ws))
    for backend in ("interpret", "xla"):
        fused = binary_binary_dense(xp, wp, threshold=0, pack_out=True,
                                    backend=backend)
        y = binary_binary_dense(xp, wp, threshold=0, backend=backend)
        unfused = binarize_pack(y.astype(jnp.float32), backend=backend)
        np.testing.assert_array_equal(np.asarray(fused.words),
                                      np.asarray(unfused.words))


# ------------------------------------------------------------------ #
# VMEM residency: the int32 [M, N] intermediate must not exist         #
# (walker lives in repro.analysis.jaxpr_audit — THE shared detector)   #
# ------------------------------------------------------------------ #
def _int32_avals(fn, *args):
    return eqn_shapes(fn, *args, dtype=jnp.int32)


def test_fused_path_has_no_int32_mn_intermediate():
    """Regression: the fused pack_out dispatch must not materialize the
    int32 [M, N] (or padded [Mp, Np]) activation anywhere — neither at
    the XLA level nor as a full-size kernel output."""
    rng = np.random.default_rng(7)
    m, k, n = 200, 64, 200          # pads to 256; kernel blocks are 128
    xs, ws = _pm1(rng, m, k), _pm1(rng, n, k)
    xp = PackedArray.pack(jnp.asarray(xs))
    wp = PackedArray.pack(jnp.asarray(ws))

    fused = _int32_avals(
        lambda a, b: binary_binary_dense(a, b, threshold=0, pack_out=True,
                                         backend="interpret").words,
        xp, wp)
    banned = {(m, n), (256, 256)}
    assert not (fused & banned), f"int32 {fused & banned} in fused path"

    # detector sanity: the unfused path DOES contain it
    unfused = _int32_avals(
        lambda a, b: binary_binary_dense(a, b, threshold=0,
                                         backend="interpret"),
        xp, wp)
    assert (256, 256) in unfused, unfused


# ------------------------------------------------------------------ #
# megakernel                                                           #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("backend", ["interpret", "xla"])
def test_fused_mlp_matches_dense_oracle(backend):
    """3-layer stack (odd widths, mixed scalar / per-channel
    thresholds) vs the dense sign-network oracle, bit for bit."""
    rng = np.random.default_rng(42)
    D, H, O, B = 96, 80, 40, 37
    x = rng.normal(size=(B, D)).astype(np.float32)
    Ws = [rng.normal(size=(H, D)).astype(np.float32),
          rng.normal(size=(H, H)).astype(np.float32),
          rng.normal(size=(O, H)).astype(np.float32)]
    tv = rng.integers(-4, 4, size=O).astype(np.int32)
    thresholds = [0, 2, jnp.asarray(tv)]
    Wp = [PackedArray.pack(jnp.asarray(w), axis=-1) for w in Ws]

    xp = binarize_pack(jnp.asarray(x), backend=backend)
    out = fused_binary_mlp(xp, Wp, thresholds, backend=backend)
    assert isinstance(out, PackedArray) and out.length == O

    h = np.where(x > 0, 1.0, -1.0)
    for w, t in zip(Ws, [0, 2, tv]):
        s = h @ np.where(w > 0, 1.0, -1.0).T
        h = np.where(s >= np.asarray(t), 1.0, -1.0)
    want = pack_words(jnp.asarray(h), axis=-1)
    np.testing.assert_array_equal(np.asarray(out.words), np.asarray(want))


def test_fused_mlp_equals_chained_layers():
    """One pallas_call for the whole stack == chaining
    binary_binary_dense(pack_out=True), words identical — and leading
    batch dims survive."""
    rng = np.random.default_rng(8)
    D, H, B = 64, 50, 6
    x = rng.normal(size=(2, B, D)).astype(np.float32)
    Ws = [rng.normal(size=(H, D)).astype(np.float32),
          rng.normal(size=(H, H)).astype(np.float32)]
    Wp = [PackedArray.pack(jnp.asarray(w), axis=-1) for w in Ws]
    xp = binarize_pack(jnp.asarray(x), backend="interpret")

    mega = fused_binary_mlp(xp, Wp, [0, 1], backend="interpret")
    h = xp
    for wp in Wp:
        h = binary_binary_dense(h, wp, threshold=0 if wp is Wp[0] else 1,
                                pack_out=True, backend="interpret")
    assert mega.words.shape == h.words.shape == (2, B, 2)
    np.testing.assert_array_equal(np.asarray(mega.words),
                                  np.asarray(h.words))


def test_fused_mlp_threshold_forms_agree_across_backends():
    """Regression: scalar thresholds in every spelling (python int,
    numpy scalar, 0-d jax array, float) must classify identically on
    kernel and oracle backends — 0-d arrays used to be rejected as
    malformed per-channel vectors on kernel backends only."""
    rng = np.random.default_rng(21)
    D, H, B = 64, 32, 5
    x = rng.normal(size=(B, D)).astype(np.float32)
    wp = [PackedArray.pack(jnp.asarray(
        rng.normal(size=(H, D)).astype(np.float32)))]
    xp_i = binarize_pack(jnp.asarray(x), backend="interpret")
    xp_x = binarize_pack(jnp.asarray(x), backend="xla")
    base = None
    for t in (0, np.int32(0), jnp.int32(0), 0.0):
        o_i = fused_binary_mlp(xp_i, wp, [t], backend="interpret")
        o_x = fused_binary_mlp(xp_x, wp, [t], backend="xla")
        np.testing.assert_array_equal(np.asarray(o_i.words),
                                      np.asarray(o_x.words))
        if base is None:
            base = np.asarray(o_i.words)
        np.testing.assert_array_equal(np.asarray(o_i.words), base)


def test_fused_mlp_clamps_tuned_bm():
    """Regression: a stale tuning-table bm that does not divide the
    padded M must be clamped like every other kernel's blocks — it
    used to shrink the grid and silently leave output rows unwritten."""
    rng = np.random.default_rng(17)
    B, D, H = 100, 64, 32                      # pads to mp = 128
    x = rng.normal(size=(B, D)).astype(np.float32)
    wp = [PackedArray.pack(jnp.asarray(
        rng.normal(size=(H, D)).astype(np.float32)))]
    xp_x = binarize_pack(jnp.asarray(x), backend="xla")
    want = fused_binary_mlp(xp_x, wp, [0], backend="xla")

    tbl = get_table()
    key = ("fused_mlp", "interpret", 128, 128, 2)   # mp, pad_n(H), w0
    tbl.put(key, BlockConfig(bm=96, bn=128, bk32=2))
    try:
        xp_i = binarize_pack(jnp.asarray(x), backend="interpret")
        got = fused_binary_mlp(xp_i, wp, [0], backend="interpret")
        np.testing.assert_array_equal(np.asarray(got.words),
                                      np.asarray(want.words))
    finally:
        tbl._entries.pop(key, None)


def test_fused_mlp_validates_chain():
    rng = np.random.default_rng(0)
    xp = PackedArray.pack(jnp.asarray(_pm1(rng, 4, 64)))
    w_bad = PackedArray.pack(jnp.asarray(_pm1(rng, 8, 32)))
    with pytest.raises(ValueError, match="incoming activation width"):
        fused_binary_mlp(xp, [w_bad], [0], backend="xla")
    with pytest.raises(ValueError, match="thresholds"):
        fused_binary_mlp(xp, [w_bad], [0, 1], backend="xla")


# ------------------------------------------------------------------ #
# folded-BN -> per-channel threshold rewrite                           #
# ------------------------------------------------------------------ #
def test_fold_to_channel_thresholds_matches_apply_folded():
    """Flip absorption: negated weight rows + T' = 1 - T reproduce
    apply_folded (incl. gamma < 0 channels) exactly, and the rewritten
    words keep pad bits zero."""
    rng = np.random.default_rng(3)
    B, D, H = 9, 70, 50
    x = rng.normal(size=(B, D)).astype(np.float32)
    w = rng.normal(size=(H, D)).astype(np.float32)
    wp, fold = quantize_for_serving(
        w, rng.normal(size=H), rng.uniform(0.5, 2.0, size=H),
        rng.normal(size=H), rng.normal(size=H))
    assert bool(np.asarray(fold.flip).any()), "need gamma<0 channels"

    xp = binarize_pack(jnp.asarray(x), backend="xla")
    want = bnn_dense_serve_folded(xp, wp, fold)          # +-1 via flip
    w2, tvec = fold_to_channel_thresholds(wp, fold)
    got = binary_binary_dense(xp, w2, threshold=tvec, backend="interpret")
    np.testing.assert_array_equal(np.asarray(want).astype(np.int32),
                                  np.asarray(got))
    # pad bits of the flipped rows stay 0 (70 % 32 != 0)
    pad_mask = ~np.uint32(0) << np.uint32(70 - 64)
    assert not np.any(np.asarray(w2.words)[:, -1] & pad_mask)


def test_bnn_mlp_serve_folded_stack():
    rng = np.random.default_rng(13)
    B, D, H = 7, 64, 48
    x = rng.normal(size=(B, D)).astype(np.float32)

    def mk(kin, kout):
        return quantize_for_serving(
            rng.normal(size=(kout, kin)).astype(np.float32),
            rng.normal(size=kout), rng.uniform(0.5, 2.0, size=kout),
            rng.normal(size=kout), rng.normal(size=kout))

    layers = [mk(D, H), mk(H, H)]
    xp = binarize_pack(jnp.asarray(x), backend="xla")
    out = bnn_mlp_serve_folded(xp, layers, backend="interpret")

    h = xp
    for wpl, fo in layers:
        y = bnn_dense_serve_folded(h, wpl, fo)
        h = PackedArray.pack(jnp.asarray(y), axis=-1)
    np.testing.assert_array_equal(np.asarray(out.words),
                                  np.asarray(h.words))


# ------------------------------------------------------------------ #
# block clamping / ValueErrors (satellite)                             #
# ------------------------------------------------------------------ #
def test_kernels_clamp_blocks_instead_of_asserting():
    """Direct kernel callers with non-128-multiple shapes get the
    largest-divisor clamp, not an AssertionError."""
    rng = np.random.default_rng(9)
    m, k, n = 96, 160, 72            # none are 128-multiples
    xs, ws = _pm1(rng, m, k), _pm1(rng, n, k)
    xp = pack_words(jnp.asarray(xs), axis=-1)
    wp = pack_words(jnp.asarray(ws), axis=-1)
    got = popcount_gemm(xp, wp, k=k, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  (xs @ ws.T).astype(np.int32))


def test_pack_out_clamps_small_tuned_bn_up():
    """A tuned/requested bn below the packing minimum (32) must clamp
    UP for pack_out launches, not explode in the divisor search."""
    rng = np.random.default_rng(12)
    m, k, n = 64, 64, 128
    xs, ws = _pm1(rng, m, k), _pm1(rng, n, k)
    xp = pack_words(jnp.asarray(xs), axis=-1)
    wp = pack_words(jnp.asarray(ws), axis=-1)
    got = popcount_gemm(xp, wp, k=k, threshold=0, pack_out=True,
                        bn=16, interpret=True)
    dec = np.where(xs @ ws.T >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(pack_words(jnp.asarray(dec), axis=-1)))


def test_kernels_raise_clear_valueerrors():
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.normal(size=(8, 40)).astype(np.float32))
    wp = pack_words(jnp.asarray(_pm1(rng, 40, 16)), axis=0)  # 2 words
    alpha = jnp.ones((16,), jnp.float32)
    with pytest.raises(ValueError, match="contraction dim"):
        xnor_gemm(x, wp, alpha, interpret=True)   # K=40 vs 2*32=64
    xs = pack_words(jnp.asarray(_pm1(rng, 8, 64)), axis=-1)
    ws = pack_words(jnp.asarray(_pm1(rng, 16, 64)), axis=-1)
    with pytest.raises(ValueError, match="pack_out requires a threshold"):
        popcount_gemm(xs, ws, k=64, pack_out=True, interpret=True)
    with pytest.raises(ValueError, match="N % 32"):
        popcount_gemm(xs, ws[:7], k=64, threshold=0, pack_out=True,
                      interpret=True)


# ------------------------------------------------------------------ #
# CSA oracle + autotuner                                               #
# ------------------------------------------------------------------ #
@given(st.integers(1, 40), st.integers(1, 120), st.integers(1, 30),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_csa_ref_equals_cube_ref(m, k, n, seed):
    """Harley-Seal restructuring is exact for any shape/bit pattern."""
    rng = np.random.default_rng(seed)
    xp = pack_words(jnp.asarray(_pm1(rng, m, k)), axis=-1)
    wp = pack_words(jnp.asarray(_pm1(rng, n, k)), axis=-1)
    np.testing.assert_array_equal(
        np.asarray(ref.popcount_gemm_csa_ref(xp, wp, k)),
        np.asarray(ref.popcount_gemm_ref(xp, wp, k)))


def test_tuning_table_roundtrip(tmp_path):
    tbl = get_table()
    cfg = best_blocks("popcount_gemm", 256, 256, 16, "interpret")
    assert (cfg.bm, cfg.bn, cfg.bk32) == (128, 128, 16)
    # heuristic result is memoized
    assert best_blocks("popcount_gemm", 256, 256, 16, "interpret") is cfg
    # divisor clamping on awkward shapes
    odd = best_blocks("popcount_gemm", 96, 72, 5, "interpret")
    assert 96 % odd.bm == 0 and 72 % odd.bn == 0 and 5 % odd.bk32 == 0
    path = tmp_path / "table.json"
    tbl.save(str(path))
    data = json.loads(path.read_text())
    assert data["popcount_gemm|interpret|256|256|16"] == \
        {"bm": 128, "bn": 128, "bk32": 16}
    tbl2 = type(tbl)()
    tbl2.load(str(path))
    assert tbl2.get(("popcount_gemm", "interpret", 256, 256, 16)) == cfg


def test_autotune_picks_fastest_candidate():
    import time

    calls = []

    def runner(cfg: BlockConfig):
        calls.append(cfg)
        if cfg.bm == 64:             # pretend 64 is the fast tile
            return
        time.sleep(0.002)

    cands = [BlockConfig(128, 128, 16), BlockConfig(64, 128, 16)]
    best = autotune("popcount_gemm", 128, 128, 16, "testbe", runner,
                    candidates=cands, iters=2)
    assert best.bm == 64
    assert best_blocks("popcount_gemm", 128, 128, 16, "testbe") is best
