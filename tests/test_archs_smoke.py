"""Per-architecture smoke tests: reduced config, one forward + one
train-grad + prefill->decode on CPU; asserts shapes and finiteness.

Also the prefill/decode equivalence test: decoding token t with a cache
built from prefill(x[:t]) must match the full forward at position t —
this exercises KV caches, ring buffers (SWA/local), SSM and RG-LRU
recurrent states for every family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import (decode_step, forward, init_caches, init_params,
                          input_specs, loss_fn, prefill)
from repro.models.layers import logits_apply
from repro.models.model import _ctx_from_inputs, apply_norm

ARCH_IDS = sorted(ARCHS)


def _batch_for(cfg, B, S, key, kind="train"):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if kind == "train":
        batch["targets"] = jax.random.randint(ks[1], (B, S), 0,
                                              cfg.vocab_size)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    elif cfg.frontend == "vision_patches":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = reduced(ARCHS[arch]).replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, key)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.abs(g.astype(jnp.float32))),
                     grads))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, \
        f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_equivalence(arch):
    """decode(cache(prefill(x[:t]))) == forward(x[:t+1])[-1] logits."""
    cfg = reduced(ARCHS[arch]).replace(dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, S = 2, 12
    capacity = 16
    batch = _batch_for(cfg, B, S + 1, key, kind="prefill")
    tokens = batch["tokens"]

    # reference: full forward over S+1 tokens
    ctx = _ctx_from_inputs(params, cfg, batch)
    x_full, _, _ = forward(params, cfg, tokens, ctx=ctx)
    emb = params.get("lm_head", params["embed"])
    ref_logits = logits_apply(emb, x_full[:, -1:], transpose=True)

    # prefill on S tokens, then decode token S
    pre = dict(batch)
    pre["tokens"] = tokens[:, :S]
    logits0, caches = prefill(params, cfg, pre, cache_capacity=capacity)
    step_batch = {
        "tokens": tokens[:, S:S + 1],
        "step": jnp.full((B,), S, jnp.int32),
        "caches": caches,
    }
    dec_logits, new_caches = decode_step(params, cfg, step_batch)

    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(ref_logits),
                               rtol=2e-2, atol=2e-2)
    # prefill's own last-token logits must also match forward at S-1
    ref_s = logits_apply(emb, x_full[:, S - 1:S], transpose=True)
    # (only valid when position S-1's logits don't depend on token S)
    np.testing.assert_allclose(np.asarray(logits0), np.asarray(ref_s),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_cover_all_shapes(arch):
    from repro.configs import SHAPES, shape_applicable
    cfg = ARCHS[arch]
    for s in SHAPES.values():
        ok, why = shape_applicable(cfg, s)
        if not ok:
            assert "full-attention" in why
            continue
        specs = input_specs(cfg, s)
        assert "tokens" in specs
        if s.kind == "decode":
            assert "caches" in specs and "step" in specs


def test_multi_step_decode_matches_forward():
    """Four consecutive decode steps against the sliding-window arch."""
    cfg = reduced(ARCHS["mixtral-8x22b"]).replace(dtype="float32")
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    B, S0, T = 2, 8, 4
    tokens = jax.random.randint(key, (B, S0 + T), 0, cfg.vocab_size)
    _, caches = prefill(params, cfg, {"tokens": tokens[:, :S0]},
                        cache_capacity=S0 + T)
    outs = []
    for t in range(T):
        batch = {"tokens": tokens[:, S0 + t:S0 + t + 1],
                 "step": jnp.full((B,), S0 + t, jnp.int32),
                 "caches": caches}
        logits, caches = decode_step(params, cfg, batch)
        outs.append(logits)
    x_full, _, _ = forward(params, cfg, tokens)
    emb = params.get("lm_head", params["embed"])
    for t in range(T):
        ref = logits_apply(emb, x_full[:, S0 + t:S0 + t + 1],
                           transpose=True)
        np.testing.assert_allclose(np.asarray(outs[t]), np.asarray(ref),
                                   rtol=2e-2, atol=2e-2)
