"""Tier-1 tests for repro.sim (DESIGN.md §14): the mesh simulator's
oracle bit-identity, measured-vs-predicted schedule parity, the DSE
sweep's determinism, and the sim layering rule."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import graph
from repro.core.energy import (CellSpecs, SystemParams, calibrate,
                               calibrate_tulip, evaluate, pe_cycles)
from repro.core.mapping import TULIP, YODANN, table3_rows
from repro.core.workloads import WORKLOADS
from repro.kernels.ops import binarize_pack
from repro.sim import MeshConfig, simulate, tree_capacity
from repro.sim.dse import pareto_front, sweep_configs

BACKENDS = ["xla", "interpret"]


# ------------------------------------------------------------------ #
# mesh model                                                           #
# ------------------------------------------------------------------ #
def test_tree_capacity_bands():
    assert tree_capacity(6) == 127
    assert tree_capacity(8) == 255
    assert tree_capacity(10) == 511
    assert tree_capacity(12) == 1023
    assert tree_capacity(16) == 1023       # accumulator cap binds
    with pytest.raises(ValueError):
        tree_capacity(5)


def test_mesh_config_validation():
    with pytest.raises(ValueError):
        MeshConfig(schedule="greedy")
    with pytest.raises(ValueError):
        MeshConfig(reg_bits=4)
    assert MeshConfig.mac_baseline().n_pes == 0
    assert MeshConfig().arch().name == TULIP.name
    assert MeshConfig.mac_baseline().arch().name == YODANN.name


def test_pe_node_cycles_matches_energy_model():
    """MeshConfig at paper defaults IS energy.pe_cycles — the sweep's
    per-config cycle hook must agree with the closed-form model on
    the config the model was calibrated for."""
    m = MeshConfig()
    rng = np.random.default_rng(0)
    ns = [1, 2, 3, 17, 255, 256, 1023, 1024, 4096, 9216]
    ns += [int(n) for n in rng.integers(1, 12000, size=20)]
    for n in ns:
        for acc in (False, True):
            for cmp_ in (False, True):
                assert m.pe_node_cycles(n, accumulate=acc,
                                        compare=cmp_) == \
                    pe_cycles(n, accumulate=acc, compare=cmp_), n


# ------------------------------------------------------------------ #
# simulator vs oracle                                                  #
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("backend", BACKENDS)
def test_mlp_sim_bit_identical(backend):
    cb = graph.compile_dense_stack(256, [128, 64, 16], backend=backend)
    params = cb.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 256), jnp.float32)
    xp = binarize_pack(x)
    r = simulate(cb, params, xp, pe_samples=2, seed=0)
    assert r.oracle_bit_identical
    assert r.counts_match_mapping
    assert r.pe_nodes_checked > 0 and r.pe_programs_ok
    assert r.run_jax_crosschecked
    assert r.energy_per_class_j > 0 and r.time_s > 0


@pytest.fixture(scope="module")
def binarynet_xla():
    """One compiled BinaryNet + calibrated system + TULIP sim run,
    shared across the BinaryNet tests (the sim is the expensive
    part)."""
    cb = graph.compile(WORKLOADS["binarynet"], backend="xla")
    params = cb.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3),
                          jnp.float32)
    cells = CellSpecs()
    system = calibrate_tulip(WORKLOADS, calibrate(WORKLOADS, cells),
                             cells)
    tulip = simulate(cb, params, x, cells=cells, system=system,
                     pe_samples=1, seed=0)
    return cb, params, x, cells, system, tulip


def test_binarynet_sim_bit_identical(binarynet_xla):
    """The paper workload end to end: simulator logits == apply, and
    the measured conv P/Z loop structure == the Table III rows."""
    cb, _, _, _, _, r = binarynet_xla
    assert r.oracle_bit_identical
    assert r.counts_match_mapping
    assert r.pe_nodes_checked > 0 and r.pe_programs_ok
    got = {d["layer"]: (d["P"], d["Z"]) for d in r.conv_pz()}
    rows = cb.table3_rows()
    assert got == {row["layer"]: (row["TULIP_P"], row["TULIP_Z"])
                   for row in rows}


def test_binarynet_sim_bit_identical_interpret():
    """Same workload with the apply oracle on the interpret backend."""
    cb = graph.compile(WORKLOADS["binarynet"], backend="interpret")
    params = cb.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3),
                          jnp.float32)
    r = simulate(cb, params, x, pe_samples=1, seed=0)
    assert r.oracle_bit_identical
    assert r.counts_match_mapping and r.pe_programs_ok


def test_binarynet_mac_baseline_and_energy_ratio(binarynet_xla):
    """The MAC mesh measures the YodaNN Table III column, identical
    logits (binary arithmetic is exact on both), and the calibrated
    model reproduces the paper's >= 3x energy headline."""
    cb, params, x, cells, system, tulip = binarynet_xla
    mac = simulate(cb, params, x, mesh=MeshConfig.mac_baseline(),
                   cells=cells, system=system, pe_samples=0, seed=0,
                   check_oracle=False)
    assert np.array_equal(tulip.logits, mac.logits)
    assert mac.counts_match_mapping
    got = {d["layer"]: (d["P"], d["Z"]) for d in mac.conv_pz()}
    rows = table3_rows(WORKLOADS["binarynet"])
    assert got == {row["layer"]: (row["YodaNN_P"], row["YodaNN_Z"])
                   for row in rows}
    ratio = mac.energy_per_class_j / tulip.energy_per_class_j
    assert ratio >= 3.0


# ------------------------------------------------------------------ #
# DSE properties                                                       #
# ------------------------------------------------------------------ #
def test_time_and_area_monotone_in_pe_count():
    """The DSE's Pareto tension is real: more PEs strictly cut wall
    time (fewer OFM refetch batches) and strictly cost area, while
    dynamic energy stays flat (same arithmetic, e_off=0)."""
    cells = CellSpecs()
    wl = WORKLOADS["binarynet"]
    sysp = SystemParams(e_off_pj=0.0)
    times, areas, energies = [], [], []
    for n in (64, 128, 256, 512):
        cfg = MeshConfig(n_pes=n)
        rep = evaluate(wl, cfg.arch(), cells, sysp,
                       cfg.pe_node_cycles)
        times.append(rep.time_s())
        areas.append(cfg.area_um2(cells))
        energies.append(rep.energy_j())
    assert all(a > b for a, b in zip(times, times[1:]))
    assert all(a < b for a, b in zip(areas, areas[1:]))
    e0 = energies[0]
    assert all(abs(e - e0) / e0 < 1e-9 for e in energies)


def test_dse_sweep_deterministic():
    cfgs1, cfgs2 = sweep_configs(smoke=True), sweep_configs(smoke=True)
    assert cfgs1 == cfgs2
    cells = CellSpecs()
    wl = WORKLOADS["binarynet"]

    def points():
        pts = []
        for cfg in sweep_configs(smoke=True):
            rep = evaluate(wl, cfg.arch(), cells, SystemParams(),
                           cfg.pe_node_cycles if cfg.n_pes else None)
            pts.append({"name": cfg.name,
                        "energy_uj": rep.energy_j() * 1e6,
                        "time_ms": rep.time_s() * 1e3,
                        "area_mm2": cfg.area_um2(cells) / 1e6})
        return pts

    f1 = [p["name"] for p in pareto_front(points())]
    f2 = [p["name"] for p in pareto_front(points())]
    assert f1 == f2 and f1           # same config set -> same frontier


def test_pareto_front_definition():
    pts = [{"e": 1.0, "t": 2.0}, {"e": 2.0, "t": 1.0},
           {"e": 2.0, "t": 2.0}, {"e": 1.0, "t": 2.0}]
    front = pareto_front(pts, keys=("e", "t"))
    assert {id(p) for p in front} == {id(pts[0]), id(pts[1]),
                                      id(pts[3])}


# ------------------------------------------------------------------ #
# layering (RPL006)                                                    #
# ------------------------------------------------------------------ #
def test_rpl006_sim_never_imports_serving(tmp_path):
    from repro.analysis.lint import lint_files

    bad = tmp_path / "sim" / "bad.py"
    bad.parent.mkdir()
    bad.write_text("from repro.serving import BNNServer\n"
                   "import repro.robustness.seu\n")
    findings = lint_files([bad], root=tmp_path)
    assert [f.rule for f in findings] == ["RPL006", "RPL006"]

    ok = tmp_path / "sim" / "ok.py"
    ok.write_text("from repro.core.energy import CellSpecs\n"
                  "from repro.graph.compile import CompiledBNN\n")
    assert lint_files([ok], root=tmp_path) == []


def test_rpl006_real_sim_package_is_clean():
    from repro.analysis.lint import lint_paths, repo_root

    sim_dir = repo_root() / "src" / "repro" / "sim"
    findings = [f for f in lint_paths([sim_dir]) if f.rule == "RPL006"]
    assert findings == []
