#!/usr/bin/env python
"""BENCH_*.json schema checker (the CI bench artifact gate).

Every benchmark artifact must carry its provenance (``env`` block:
jax version, backend, device kind/count) so a number is never compared
against a run from a different runtime.  Serve artifacts
(BENCH_serve*.json) additionally carry the ISSUE 6 serving schema —
throughput, device scaling, the continuous-batching stream, and the
ragged-padding table — which this tool validates structurally on every
smoke run, so a refactor that silently drops a field (or stops
measuring a claim) fails CI even when the bench itself ran green.
Faults artifacts (BENCH_faults*.json, ISSUE 7) carry the SEU /
threshold-noise curves and the chaos recovery row; their recovery
invariants (zero lost futures, poison isolation, bit-identical
fallback) are enforced unconditionally — on smoke and full runs alike.
Train artifacts (BENCH_train*.json, ISSUE 8) carry the closed
train->fold->compile->serve loop; the bit-consistency invariants
(folded serving forward EXACTLY equals the training eval forward,
including through BNNServer, checkpoint round-trip exact) and the
eval-accuracy-beats-chance-by-margin gate are likewise unconditional.
DSE artifacts (BENCH_dse*.json, ISSUE 10) carry the mesh-simulator
reproduction of the paper's SS-V comparison: per-workload execution
gates (simulator logits bit-identical to the CompiledBNN.apply
oracle AND to the MAC baseline, sampled PE programs correct,
measured P/Z loop counts equal to table3_rows()) and the headline
energy_ratio_vs_mac >= min_energy_ratio (the paper's "at least 3x")
are enforced unconditionally, plus the Pareto fronts must reference
only swept config names.

``--gate`` additionally enforces the full-run perf acceptance criteria
on a tracked (non-smoke) serve artifact:

* ``scaling.speedup > 1`` — the whole-host mesh beats 1 device through
  the production dispatch path;
* ``overhead_vs_exact < 1.5`` on every ragged padding point — masked
  bucket dispatch never pays 1.5x over a jit traced at exactly the
  request's shape.

Usage: python tools/check_bench_schema.py [--gate] FILE [FILE ...]
Exit status 1 with one line per violation, 0 when clean.
Dependency-free on purpose: the docs/CI jobs run it without jax.
"""
from __future__ import annotations

import argparse
import json
import sys

ENV_KEYS = ("jax_version", "backend", "device_kind", "device_count")
SERVE_TOP = ("env", "devices", "smoke", "model", "throughput",
             "scaling", "stream", "padding", "server_stats",
             "bit_identity")
THROUGHPUT_KEYS = ("batch", "wall_s", "rows_per_s")
SCALING_KEYS = ("batch", "devices_1_wall_s")
SCALING_MESH_KEYS = ("devices_n", "devices_n_wall_s", "speedup")
STREAM_KEYS = ("requests", "rows_each", "rows_total", "sync_wall_s",
               "stream_wall_s", "pipeline_speedup", "rows_per_s_stream",
               "dispatches_per_run", "inflight_peak")
PADDING_KEYS = ("rows", "bucket", "valid", "wall_s",
                "exact_jit_wall_s", "bucket_jit_wall_s", "occupancy",
                "compute_occupancy", "overhead_vs_exact")
FAULTS_TOP = ("env", "smoke", "model", "seu", "thresholds", "chaos")
SEU_KEYS = ("n_flips", "argmax_match", "mean_abs_logit_delta",
            "max_abs_logit_delta")
THRESH_KEYS = ("sigma", "argmax_match", "mean_abs_logit_delta",
               "max_abs_logit_delta")
CHAOS_KEYS = ("requests", "zero_lost_futures", "poison_isolated",
              "fallback_bit_identical", "flight_faults",
              "backend_fallbacks", "retries", "bisections",
              "poisoned_requests", "timeouts", "thread_restarts",
              "storm_wall_s")
# Invariants, not perf numbers: they must hold on smoke and full runs
# alike, so check_faults enforces them unconditionally (no --gate).
CHAOS_INVARIANTS = ("zero_lost_futures", "poison_isolated",
                    "fallback_bit_identical")
TRAIN_TOP = ("env", "smoke", "models")
TRAIN_MODEL_KEYS = ("name", "steps", "global_batch", "num_classes",
                    "chance", "margin", "first_train_loss",
                    "final_train_loss", "loss_curve", "train_acc_final",
                    "eval_acc", "eval_loss", "eval_rows",
                    "latent_eval_acc", "binarization_gap",
                    "fold_bit_consistent", "serve_bit_consistent",
                    "ckpt_roundtrip_exact", "sign_identity_rows",
                    "wall_train_s", "steps_per_s")
# The train->fold->compile->serve contract (ISSUE 8): bit-consistency
# and the learning gate hold on smoke and full artifacts alike.
TRAIN_INVARIANTS = ("fold_bit_consistent", "serve_bit_consistent",
                    "ckpt_roundtrip_exact")
DSE_TOP = ("smoke", "min_energy_ratio", "calibration",
           "default_config", "workloads", "sweep", "pareto_fronts",
           "comparison_points")
DSE_WORKLOAD_KEYS = ("name", "dataset", "batch",
                     "oracle_bit_identical", "mac_logits_bit_identical",
                     "pe_programs_checked", "pe_programs_ok",
                     "run_jax_crosschecked", "cycles_match_table3",
                     "matches_closed_form", "table3", "tulip",
                     "mac_baseline", "energy_ratio_vs_mac")
DSE_METRIC_KEYS = ("config", "energy_uj", "time_ms", "ops_mop",
                   "perf_gops", "eff_tops_w", "area_mm2",
                   "wall_cycles")
DSE_SWEEP_KEYS = ("workload", "name", "n_pes", "reg_bits", "schedule",
                  "n_macs", "energy_uj", "time_ms", "area_mm2",
                  "eff_tops_w", "pareto")
# The simulator contract (ISSUE 10): execution correctness gates hold
# on smoke and full artifacts alike — an artifact whose simulator
# diverged from the oracle, or whose measured loop counts disagree
# with table3_rows(), is broken regardless of run size.
DSE_INVARIANTS = ("oracle_bit_identical", "mac_logits_bit_identical",
                  "pe_programs_ok", "cycles_match_table3")


def _missing(obj, keys, where):
    return [f"{where}: missing key '{k}'" for k in keys if k not in obj]


def _positive(obj, keys, where):
    errs = []
    for k in keys:
        v = obj.get(k)
        if isinstance(v, (int, float)) and k.endswith(
                ("_s", "_per_s", "speedup")) and v <= 0:
            errs.append(f"{where}: '{k}' must be > 0, got {v}")
    return errs


def check_env(doc, path):
    errs = []
    env = doc.get("env")
    if not isinstance(env, dict):
        return [f"{path}: missing 'env' provenance block"]
    errs += _missing(env, ENV_KEYS, f"{path}: env")
    if not isinstance(env.get("jax_version", ""), str) or \
            not env.get("jax_version"):
        errs.append(f"{path}: env.jax_version must be a non-empty string")
    if not isinstance(env.get("device_count", 0), int) or \
            env.get("device_count", 0) < 1:
        errs.append(f"{path}: env.device_count must be a positive int")
    return errs


def check_serve(doc, path):
    """BENCH_serve*.json (ISSUE 6): throughput, scaling, stream, and
    ragged-padding tables plus the bit-identity row; structural on
    every run, perf thresholds only under --gate."""
    errs = _missing(doc, SERVE_TOP, path)
    if errs:
        return errs                      # later checks would just KeyError
    thr = doc["throughput"]
    if not isinstance(thr, list) or not thr:
        errs.append(f"{path}: 'throughput' must be a non-empty list")
    else:
        for i, row in enumerate(thr):
            errs += _missing(row, THROUGHPUT_KEYS, f"{path}: throughput[{i}]")
            errs += _positive(row, THROUGHPUT_KEYS, f"{path}: throughput[{i}]")
    sc = doc["scaling"]
    errs += _missing(sc, SCALING_KEYS, f"{path}: scaling")
    if doc["devices"] > 1:
        errs += _missing(sc, SCALING_MESH_KEYS, f"{path}: scaling")
    errs += _positive(sc, SCALING_KEYS + SCALING_MESH_KEYS,
                      f"{path}: scaling")
    errs += _missing(doc["stream"], STREAM_KEYS, f"{path}: stream")
    errs += _positive(doc["stream"], STREAM_KEYS, f"{path}: stream")
    pad = doc["padding"]
    if not isinstance(pad, list) or not pad:
        errs.append(f"{path}: 'padding' must be a non-empty list")
    else:
        for i, row in enumerate(pad):
            errs += _missing(row, PADDING_KEYS, f"{path}: padding[{i}]")
    if not isinstance(doc["server_stats"], dict):
        errs.append(f"{path}: 'server_stats' must be an object")
    return errs


def check_faults(doc, path):
    """BENCH_faults*.json: fault-injection curves + chaos recovery row
    (ISSUE 7).  Curve sanity (a zero-injection point that is exactly
    the healthy forward) and the recovery invariants are validated on
    every artifact — a faults bench whose server lost a future is a
    broken artifact, not a slow one."""
    errs = _missing(doc, FAULTS_TOP, path)
    if errs:
        return errs
    for name, keys, zero_key in (("seu", SEU_KEYS, "n_flips"),
                                 ("thresholds", THRESH_KEYS, "sigma")):
        rows = doc[name]
        if not isinstance(rows, list) or not rows:
            errs.append(f"{path}: '{name}' must be a non-empty list")
            continue
        for i, row in enumerate(rows):
            errs += _missing(row, keys, f"{path}: {name}[{i}]")
        z = rows[0]
        if z.get(zero_key) == 0 and (z.get("argmax_match") != 1.0 or
                                     z.get("max_abs_logit_delta") != 0):
            errs.append(f"{path}: {name}[0] is a zero-injection point "
                        f"but is not bit-identical to the healthy run")
    chaos = doc["chaos"]
    if not isinstance(chaos, dict):
        return errs + [f"{path}: 'chaos' must be an object"]
    errs += _missing(chaos, CHAOS_KEYS, f"{path}: chaos")
    for k in CHAOS_INVARIANTS:
        if k in chaos and chaos[k] is not True:
            errs.append(f"{path}: chaos.{k} = {chaos[k]} — recovery "
                        f"invariant violated")
    return errs


def check_train(doc, path):
    """BENCH_train*.json (ISSUE 8): the closed training loop.  The
    bit-consistency invariants and the accuracy-beats-chance gate are
    enforced unconditionally — a training artifact whose folded serving
    forward diverged, or whose model never learned the separable
    synthetic task, is a broken artifact on any run size."""
    errs = _missing(doc, TRAIN_TOP, path)
    if errs:
        return errs
    models = doc["models"]
    if not isinstance(models, list) or not models:
        return [f"{path}: 'models' must be a non-empty list"]
    for i, row in enumerate(models):
        where = f"{path}: models[{i}]"
        errs += _missing(row, TRAIN_MODEL_KEYS, where)
        errs += _positive(row, TRAIN_MODEL_KEYS, where)
        for k in TRAIN_INVARIANTS:
            if k in row and row[k] is not True:
                errs.append(f"{where}: {k} = {row[k]} — the "
                            f"train->serve contract is violated")
        acc, chance, margin = (row.get("eval_acc"), row.get("chance"),
                               row.get("margin"))
        if isinstance(acc, (int, float)) and \
                isinstance(chance, (int, float)) and \
                isinstance(margin, (int, float)) and \
                acc <= chance + margin:
            errs.append(f"{where}: eval_acc = {acc:.3f} does not beat "
                        f"chance {chance:.2f} + margin {margin:.2f}")
        fl, ll = row.get("first_train_loss"), row.get("final_train_loss")
        if isinstance(fl, (int, float)) and isinstance(ll, (int, float)) \
                and ll >= fl:
            errs.append(f"{where}: final_train_loss {ll:.4f} did not "
                        f"improve on first_train_loss {fl:.4f}")
        curve = row.get("loss_curve")
        if curve is not None and (not isinstance(curve, list) or
                                  len(curve) < 2):
            errs.append(f"{where}: loss_curve must be a list of >= 2 "
                        f"points")
    return errs


def check_dse(doc, path):
    """BENCH_dse*.json (ISSUE 10): the mesh-simulator DSE artifact.
    Per-workload execution gates (oracle/MAC bit-identity, PE-program
    fidelity, table3 loop-count parity) and the >= min_energy_ratio
    headline are enforced unconditionally; the sweep must be
    internally consistent (every Pareto-front name is a swept config
    for that workload, every front row is flagged pareto)."""
    dse = doc.get("dse")
    if not isinstance(dse, dict):
        return [f"{path}: 'dse' must be an object"]
    errs = _missing(dse, DSE_TOP, f"{path}: dse")
    if errs:
        return errs
    ratio_floor = dse["min_energy_ratio"]
    wls = dse["workloads"]
    if not isinstance(wls, list) or not wls:
        return [f"{path}: dse.workloads must be a non-empty list"]
    for i, row in enumerate(wls):
        where = f"{path}: dse.workloads[{i}]"
        errs += _missing(row, DSE_WORKLOAD_KEYS, where)
        for k in DSE_INVARIANTS:
            if k in row and row[k] is not True:
                errs.append(f"{where}: {k} = {row[k]} — the simulator "
                            f"correctness contract is violated")
        ratio = row.get("energy_ratio_vs_mac")
        if isinstance(ratio, (int, float)) and \
                isinstance(ratio_floor, (int, float)) and \
                ratio < ratio_floor:
            errs.append(f"{where}: energy_ratio_vs_mac = {ratio:.3f} "
                        f"below the paper's {ratio_floor}x claim")
        checked = row.get("pe_programs_checked")
        if isinstance(checked, int) and checked < 1:
            errs.append(f"{where}: pe_programs_checked = {checked} — "
                        f"no PE program was actually executed")
        for side in ("tulip", "mac_baseline"):
            m = row.get(side)
            if isinstance(m, dict):
                errs += _missing(m, DSE_METRIC_KEYS, f"{where}.{side}")
    sweep = dse["sweep"]
    if not isinstance(sweep, list) or not sweep:
        errs.append(f"{path}: dse.sweep must be a non-empty list")
        sweep = []
    for i, row in enumerate(sweep):
        errs += _missing(row, DSE_SWEEP_KEYS, f"{path}: dse.sweep[{i}]")
    fronts = dse["pareto_fronts"]
    if not isinstance(fronts, dict) or not fronts:
        errs.append(f"{path}: dse.pareto_fronts must be a non-empty "
                    f"object")
        fronts = {}
    for wl_name, names in fronts.items():
        flagged = {r.get("name") for r in sweep
                   if r.get("workload") == wl_name and r.get("pareto")}
        if set(names) != flagged:
            errs.append(f"{path}: dse.pareto_fronts['{wl_name}'] does "
                        f"not match the pareto-flagged sweep rows")
    return errs


def gate_serve(doc, path):
    """The full-run perf acceptance criteria (never applied to smoke
    artifacts: smoke shapes only measure dispatch overhead)."""
    errs = []
    if doc.get("smoke"):
        errs.append(f"{path}: --gate on a smoke artifact — the tracked "
                    f"BENCH_serve.json must come from a full run")
        return errs
    speedup = doc.get("scaling", {}).get("speedup")
    if speedup is None:
        errs.append(f"{path}: no scaling.speedup (single-device run?)")
    elif speedup <= 1.0:
        errs.append(f"{path}: scaling.speedup = {speedup:.3f} — the mesh "
                    f"must beat 1 device (> 1.0)")
    for row in doc.get("padding", []):
        ov = row.get("overhead_vs_exact")
        if ov is None or ov >= 1.5:
            errs.append(f"{path}: padding rows={row.get('rows')} "
                        f"overhead_vs_exact = {ov} — must be < 1.5")
    return errs


def check_file(path, gate=False):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    errs = check_env(doc, path)
    is_serve = "throughput" in doc or "scaling" in doc
    is_faults = "seu" in doc and "chaos" in doc
    is_train = "models" in doc
    is_dse = "dse" in doc
    if is_serve:
        errs += check_serve(doc, path)
        if gate and not errs:
            errs += gate_serve(doc, path)
    elif is_faults:
        errs += check_faults(doc, path)
        if gate:
            errs.append(f"{path}: --gate only applies to serve "
                        f"artifacts (faults invariants are always on)")
    elif is_train:
        errs += check_train(doc, path)
        if gate:
            errs.append(f"{path}: --gate only applies to serve "
                        f"artifacts (train invariants are always on)")
    elif is_dse:
        errs += check_dse(doc, path)
        if gate:
            errs.append(f"{path}: --gate only applies to serve "
                        f"artifacts (dse invariants are always on)")
    elif gate:
        errs.append(f"{path}: --gate only applies to serve artifacts")
    return errs


# artifact kind -> (detector keys, validator, unconditional invariants)
# — what --list-schemas prints, and the single place a new artifact
# family gets registered.
SCHEMAS = {
    "serve": ("throughput|scaling", "check_serve",
              "bit_identity (--gate adds speedup/padding perf)"),
    "faults": ("seu&chaos", "check_faults",
               "+".join(CHAOS_INVARIANTS)),
    "train": ("models", "check_train",
              "+".join(TRAIN_INVARIANTS) + "+eval_acc>chance+margin"),
    "dse": ("dse", "check_dse",
            "+".join(DSE_INVARIANTS) + "+ratio>=min_energy_ratio"),
}


def list_schemas():
    print("artifact schemas (kind: detector keys -> validator; "
          "unconditional invariants):")
    for kind, (keys, fn, invariants) in SCHEMAS.items():
        print(f"  {kind}: {keys} -> {fn}; invariants: {invariants}")
        if globals()[fn].__doc__ is None:
            raise AssertionError(f"{fn} lost its docstring")
    print(f"env provenance (all kinds): {', '.join(ENV_KEYS)}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="validate BENCH_*.json artifact schemas")
    ap.add_argument("files", nargs="*")
    ap.add_argument("--gate", action="store_true",
                    help="also enforce the full-run serve perf gates "
                         "(speedup > 1, padding overhead < 1.5)")
    ap.add_argument("--list-schemas", action="store_true",
                    help="print the registered artifact kinds, their "
                         "validators and invariants, then exit")
    args = ap.parse_args(argv)
    if args.list_schemas:
        return list_schemas()
    if not args.files:
        ap.error("at least one FILE is required (or --list-schemas)")
    errors = []
    for path in args.files:
        errors += check_file(path, gate=args.gate)
    for e in errors:
        print(e, file=sys.stderr)
    if not errors:
        print(f"bench schema OK ({len(args.files)} artifact(s)"
              f"{', gates enforced' if args.gate else ''})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
