#!/usr/bin/env python
"""Intra-repo Markdown link checker (the CI docs gate).

Scans ``[text](target)`` links in the given Markdown files and fails
when a *relative* target does not resolve:

* ``path`` / ``path#anchor`` → the file (or directory) must exist,
  relative to the linking file's directory;
* ``#anchor`` (same-file) and ``path#anchor`` → the target file must
  contain a heading whose GitHub slug matches the anchor;
* external schemes (http/https/mailto) are skipped — this gate is
  about the repo's own docs never dangling, not the internet.

Usage: python tools/check_links.py README.md DESIGN.md [...]
Exit status 1 with one line per broken link, 0 when clean.
"""
from __future__ import annotations

import re
import sys
import unicodedata
from pathlib import Path

LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation (keep word
    chars/hyphens/spaces), spaces -> hyphens."""
    text = re.sub(r"[`*_]|\[|\]|\(#?[^)]*\)", "", heading).strip()
    text = unicodedata.normalize("NFKD", text).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    text = CODE_FENCE.sub("", md_path.read_text(encoding="utf-8"))
    return {github_slug(h) for h in HEADING.findall(text)}


def check_file(md_path: Path, repo_root: Path) -> list:
    errors = []
    text = CODE_FENCE.sub("", md_path.read_text(encoding="utf-8"))
    for target in LINK.findall(text):
        if target.startswith(EXTERNAL):
            continue
        path_part, _, anchor = target.partition("#")
        if path_part:
            dest = (md_path.parent / path_part).resolve()
            try:
                dest.relative_to(repo_root)
            except ValueError:
                errors.append(f"{md_path.name}: link escapes the repo: "
                              f"{target}")
                continue
            if not dest.exists():
                errors.append(f"{md_path.name}: missing target: {target}")
                continue
            if anchor and dest.suffix == ".md":
                if github_slug(anchor) not in anchors_of(dest):
                    errors.append(f"{md_path.name}: missing anchor "
                                  f"#{anchor} in {path_part}")
        elif anchor:
            if github_slug(anchor) not in anchors_of(md_path):
                errors.append(f"{md_path.name}: missing same-file "
                              f"anchor #{anchor}")
    return errors


def main(argv) -> int:
    if not argv:
        print("usage: check_links.py FILE.md [FILE.md ...]")
        return 2
    repo_root = Path(__file__).resolve().parent.parent
    errors = []
    for name in argv:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file not found")
            continue
        errors.extend(check_file(p.resolve(), repo_root))
    for e in errors:
        print(f"BROKEN LINK  {e}")
    if not errors:
        print(f"link check OK ({len(argv)} files)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
